"""The sparse node-axis engine vs its dense oracle.

The acceptance pins for `Experiment(layout="sparse")`:

  1. oracle — on ≤64-node BA/ER/star worlds the sparse edge-list engine is
     BIT-EQUAL to the dense padded engine: final params, total comm bytes,
     and the per-round trigger history, across methods × comm configs (at
     participation=1.0, where the two layouts consume identical rng);
  2. backends — the sparse layout lowers to shard_map bit-identically to
     vmap (single-pod here, the forced 4-device mesh in the multihost
     lane);
  3. kernels — `segment_neighbor_avg` is bitwise invariant to row
     blocking, K zero-padding (finite garbage under zero weight), and
     feature-column tiling: the properties the oracle equality rests on;
  4. plan — `build_sparse_plan` lays every node out exactly once, in the
     contiguous pod blocks shard_map slices, with the same ω·|D_src|
     weight product as the dense layout;
  5. errors — layout support is CAPABILITY-driven: the strategy's
     Capabilities record (plus the one derived restriction — a gossip
     strategy without a flat_aggregate form) decides what constructs, and
     the rejection message lists exactly which layouts support the method.
     The historical sparse carve-outs (dynamics, per-edge transport,
     CFA-GE) are lifted — their equivalence pins live in
     tests/test_sparse_parity.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig
from repro.dynamics import EdgeDropout
from repro.engine import Experiment, Schedule, World
from repro.engine.neighborhood import (
    DenseNeighborhood,
    SparseNeighborhood,
    _bucket_width,
    build_sparse_plan,
)
from repro.graphs.sparse import (
    SparseTopology,
    sparse_barabasi_albert,
    sparse_erdos_renyi,
    sparse_ring,
    sparse_star,
)
from repro.kernels import segment_avg as _sa
from repro.kernels.ops import (
    dequant_segment_neighbor_avg,
    segment_neighbor_avg,
)


def _world(st: SparseTopology, seed: int = 0, dim: int = 16,
           per_node: int = 4, classes: int = 10) -> World:
    """A node-axis-sized world (tiny model, tiny shards) over `st`."""
    from repro.models.mlp_cnn import make_mlp

    rng = np.random.default_rng(seed)
    n = st.num_nodes
    xs = [rng.normal(size=(per_node, dim)).astype(np.float32)
          for _ in range(n)]
    ys = [rng.integers(0, classes, size=per_node).astype(np.int32)
          for _ in range(n)]
    return World(
        model=make_mlp(num_classes=classes, input_dim=dim, hidden=(16,)),
        topo=st, xs=xs, ys=ys,
        x_test=rng.normal(size=(32, dim)).astype(np.float32),
        y_test=rng.integers(0, classes, size=32).astype(np.int32))


TINY = dict(steps_per_round=1, batch_size=4, lr=0.1, eval_batch=32, seed=3)


def _run(world, method, layout, rounds=3, comm=None, backend="vmap", **kw):
    exp = Experiment(world, method, comm=comm, backend=backend,
                     layout=layout,
                     schedule=Schedule(rounds=rounds, eval_every=rounds,
                                       mode="loop"),
                     **{**TINY, **kw})
    exp.run()
    return exp


def _assert_experiments_bit_equal(a: Experiment, b: Experiment):
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    assert a.comm_bytes_total == b.comm_bytes_total
    assert a.trig_history == b.trig_history


# ------------------------------------------------------------------ oracle


@pytest.fixture(scope="module")
def ba_world():
    return _world(sparse_barabasi_albert(n=16, m=2, seed=0))


@pytest.mark.parametrize("method", ["decavg", "cfa", "decdiff+vt", "fedavg",
                                    "isol"])
def test_sparse_matches_dense_per_method(ba_world, method):
    dense = _run(ba_world, method, "dense")
    sparse = _run(ba_world, method, "sparse")
    _assert_experiments_bit_equal(dense, sparse)


@pytest.mark.parametrize("st", [
    sparse_erdos_renyi(n=24, p=0.25, seed=1),
    sparse_barabasi_albert(n=24, m=1, seed=2),  # hub-heavy tree
    sparse_star(17),                            # max_degree = N - 1
], ids=["er24", "ba24-m1", "star17"])
def test_sparse_matches_dense_per_graph(st):
    world = _world(st, seed=1)
    dense = _run(world, "decdiff", "dense")
    sparse = _run(world, "decdiff", "sparse")
    _assert_experiments_bit_equal(dense, sparse)


@pytest.mark.parametrize("comm", [
    CommConfig(codec="int8", trigger_threshold=0.0),
    CommConfig(codec="fp32", trigger_threshold=0.05, on_silence="stale"),
    CommConfig(codec="fp32", trigger_threshold=0.05, on_silence="drop"),
], ids=["int8", "fp32-trig-stale", "fp32-trig-drop"])
def test_sparse_matches_dense_with_transport(ba_world, comm):
    """Per-node transport over the sparse layout: params, BYTES, and the
    trigger history reproduce the dense engine bit-for-bit (the byte
    accounting multiplies fired gates into in-degrees, a quantity both
    layouts derive from their own edge structure)."""
    dense = _run(ba_world, "decdiff", "dense", comm=comm)
    sparse = _run(ba_world, "decdiff", "sparse", comm=comm)
    assert dense.comm_bytes_total > 0
    _assert_experiments_bit_equal(dense, sparse)


def test_sparse_participation_runs_and_stays_finite(ba_world):
    """participation < 1 draws per-[N,max_deg]-slot uniforms on the dense
    layout and per-directed-edge uniforms on the sparse one — the streams
    are documented as different, so this is a liveness pin, not an
    equality pin."""
    exp = _run(ba_world, "decdiff", "sparse", participation=0.5)
    for leaf in jax.tree.leaves(exp.params):
        assert np.isfinite(np.asarray(leaf)).all()


# ----------------------------------------------------------------- backends


def test_sparse_shardmap_single_pod_matches_vmap(ba_world):
    vm = _run(ba_world, "decdiff", "sparse", backend="vmap")
    sm = _run(ba_world, "decdiff", "sparse", backend="shard_map")
    _assert_experiments_bit_equal(vm, sm)


@pytest.mark.multihost
@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs >= 4 devices (forced-multihost CI lane)")
@pytest.mark.parametrize("comm", [None,
                                  CommConfig(codec="int8",
                                             trigger_threshold=0.05)],
                         ids=["plain", "int8-trig"])
def test_sparse_shardmap_four_pods_matches_vmap(ba_world, comm):
    """The real pod split: 4 pods × 4 nodes, each pod reducing its own
    degree buckets from the all_gathered table — bit-equal to vmap."""
    vm = _run(ba_world, "decdiff", "sparse", comm=comm, backend="vmap")
    sm = _run(ba_world, "decdiff", "sparse", comm=comm, backend="shard_map")
    _assert_experiments_bit_equal(vm, sm)


# ------------------------------------------------------------------ kernels


def _rows_ref(w, v):
    """The contract: each receiver row contracted by its OWN einsum."""
    return jnp.stack([
        jnp.einsum("k,kd->d", w[r], v[r],
                   preferred_element_type=jnp.float32)
        for r in range(w.shape[0])])


def test_segment_avg_chunk_bitwise_per_row():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(_sa.ROWS, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(_sa.ROWS, 8, 256)).astype(np.float32))
    out = _sa.segment_avg_chunk(w, v)
    assert np.array_equal(np.asarray(out), np.asarray(_rows_ref(w, v)))


def test_dequant_segment_avg_chunk_bitwise_per_row():
    rng = np.random.default_rng(1)
    ws = jnp.asarray(rng.normal(size=(_sa.ROWS, 8)).astype(np.float32))
    q = jnp.asarray(rng.integers(-127, 128, size=(_sa.ROWS, 8, 256),
                                 dtype=np.int8))
    out = _sa.dequant_segment_avg_chunk(ws, q)
    ref = _rows_ref(ws, q.astype(jnp.float32))
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_segment_neighbor_avg_row_block_invariant():
    """sums[i] must not depend on which rows share the batch — the property
    that makes a pod's block reduce bit-equal to vmap's full-N reduce."""
    rng = np.random.default_rng(2)
    b, k, d = 21, 8, 100
    vals = jnp.asarray(rng.normal(size=(b, k, d)).astype(np.float32))
    w = jnp.asarray((rng.random((b, k)) < 0.7).astype(np.float32)
                    * rng.uniform(0.5, 2.0, (b, k)).astype(np.float32))
    sums, tot = segment_neighbor_avg(vals, w)
    for i in range(0, b, 5):
        s1, t1 = segment_neighbor_avg(vals[i:i + 1], w[i:i + 1])
        assert np.array_equal(np.asarray(sums[i]), np.asarray(s1[0]))
        assert np.array_equal(np.asarray(tot[i]), np.asarray(t1[0]))


def test_segment_neighbor_avg_k_pad_garbage_invariant():
    """Zero-weight slots with FINITE garbage values are bit-neutral: the
    dense max_deg padding and the sparse power-of-two bucket padding may
    hold anything."""
    rng = np.random.default_rng(3)
    b, k, d = 8, 5, 64
    vals = jnp.asarray(rng.normal(size=(b, k, d)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.5, 2.0, (b, k)).astype(np.float32))
    sums, tot = segment_neighbor_avg(vals, w)
    garbage = jnp.full((b, 11, d), 3.4e38, jnp.float32)
    vals_pad = jnp.concatenate([vals, garbage], axis=1)
    w_pad = jnp.concatenate([w, jnp.zeros((b, 11), jnp.float32)], axis=1)
    sums_p, tot_p = segment_neighbor_avg(vals_pad, w_pad)
    assert np.array_equal(np.asarray(sums), np.asarray(sums_p))
    assert np.array_equal(np.asarray(tot), np.asarray(tot_p))


def test_segment_neighbor_avg_totals_ride_the_contraction():
    rng = np.random.default_rng(4)
    b, k, d = 9, 6, 40
    vals = jnp.asarray(rng.normal(size=(b, k, d)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.0, 2.0, (b, k)).astype(np.float32))
    _, tot = segment_neighbor_avg(vals, w)
    assert np.allclose(np.asarray(tot), np.asarray(w).sum(axis=1), rtol=1e-6)


def test_dequant_segment_neighbor_avg_matches_reference():
    rng = np.random.default_rng(5)
    b, k, d = 8, 8, 96
    q = jnp.asarray(rng.integers(-127, 128, size=(b, k, d), dtype=np.int8))
    scales = jnp.asarray(rng.uniform(0.01, 0.1, (b, k)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.0, 2.0, (b, k)).astype(np.float32))
    out = dequant_segment_neighbor_avg(q, scales, w)
    ref = _rows_ref(w * scales, q.astype(jnp.float32))
    assert np.array_equal(np.asarray(out), np.asarray(ref))


# --------------------------------------------------------------------- plan


def test_bucket_width_floor_and_pow2():
    assert [_bucket_width(d) for d in (0, 1, 7, 8, 9, 16, 17, 100)] == \
        [8, 8, 8, 8, 16, 16, 32, 128]


def test_sparse_plan_star_layout():
    """Star: the hub lands in a wide bucket, the leaves in the width-8
    floor bucket; weights carry ω_e·|D_src| exactly."""
    n = 16
    st = sparse_star(n)
    rng = np.random.default_rng(7)
    counts = rng.integers(1, 9, n).astype(np.int32)
    plan = build_sparse_plan(st, counts, n_pods=2)
    assert plan.per_pod == 8 and plan.n_pods == 2
    assert plan.num_directed == st.num_directed
    assert plan.widths == (8, 16)
    assert np.array_equal(np.asarray(plan.degrees),
                          st.degrees.astype(np.float32))
    # every node appears in exactly one bucket row of its own pod
    seen = np.zeros(n, np.int64)
    for wd in plan.widths:
        bk = plan.buckets[wd]
        p_, b_, k_ = bk.src.shape
        assert p_ == 2 and k_ == wd
        assert bk.wgt.shape == (p_, b_, k_) and bk.epos.shape == (p_, b_, k_)
        for p in range(2):
            for row in range(b_):
                rl = int(bk.rows_local[p, row])
                if rl == plan.per_pod:  # trash row: inert padding
                    assert np.asarray(bk.wgt[p, row]).sum() == 0
                    continue
                i = p * plan.per_pod + rl
                seen[i] += 1
                lo, hi = int(st.row_offsets[i]), int(st.row_offsets[i + 1])
                deg = hi - lo
                assert _bucket_width(deg) == wd
                assert np.array_equal(np.asarray(bk.src[p, row, :deg]),
                                      st.edge_src[lo:hi])
                assert np.array_equal(np.asarray(bk.epos[p, row, :deg]),
                                      np.arange(lo, hi))
                ref_w = (st.edge_weight[lo:hi]
                         * counts[st.edge_src[lo:hi]].astype(np.float32))
                assert np.array_equal(np.asarray(bk.wgt[p, row, :deg]), ref_w)
                assert (np.asarray(bk.wgt[p, row, deg:]) == 0).all()
    assert (seen == 1).all()


def test_sparse_plan_rejects_non_tiling_pods():
    st = sparse_star(17)
    with pytest.raises(ValueError, match="do not tile"):
        build_sparse_plan(st, np.ones(17, np.int32), n_pods=2)


def test_neighborhood_views_bit_equal():
    """DenseNeighborhood vs SparseNeighborhood on the same star graph and
    model table: reduce / reduce_delta / n_active all bit-equal — the unit
    form of the end-to-end oracle pins above."""
    n, d = 17, 23
    st = sparse_star(n)
    topo = st.to_topology()
    rng = np.random.default_rng(8)
    counts = rng.integers(1, 9, n).astype(np.int32)
    table = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    gate = jnp.asarray((rng.random(n) < 0.6).astype(np.float32))

    idx = np.maximum(topo.neighbor_idx.astype(np.int32), 0)
    w_dense = (topo.neighbor_weights()
               * counts[idx].astype(np.float32)
               * topo.neighbor_mask)
    w_dense = jnp.asarray(w_dense) * gate[jnp.asarray(idx)]
    dn = DenseNeighborhood(table, jnp.asarray(idx), w_dense, table,
                           unflatten_fn=lambda x: x)

    plan = build_sparse_plan(st, counts, n_pods=1)
    sn = SparseNeighborhood(plan, jnp.int32(0), table, table,
                            unflatten_fn=lambda x: x, gate_vec=gate,
                            link_u=None, participation=1.0)

    for a, b in zip(dn.reduce(), sn.reduce()):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(dn.reduce_delta(), sn.reduce_delta()):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert np.array_equal(np.asarray(dn.n_active()),
                          np.asarray(sn.n_active()))


# ------------------------------------------------------------------- errors


def test_lifted_combinations_construct_on_sparse(ba_world):
    """The three historical sparse carve-outs — dynamics, per-edge
    transport, CFA-GE — all construct now (their bit-parity pins live in
    tests/test_sparse_parity.py)."""
    from repro.comm import SparseEdgeGossipTransport

    world = dataclasses.replace(ba_world, dynamics=EdgeDropout(p=0.2))
    exp = Experiment(world, "decdiff", layout="sparse",
                     schedule=Schedule(rounds=1, eval_every=1, mode="loop"),
                     **TINY)
    assert exp.bound_dyn is not None
    exp = Experiment(ba_world, "decdiff", layout="sparse",
                     comm=CommConfig(codec="int8", per_edge=True),
                     schedule=Schedule(rounds=1, eval_every=1, mode="loop"),
                     **TINY)
    assert isinstance(exp.transport, SparseEdgeGossipTransport)
    exp = Experiment(ba_world, "cfa-ge", layout="sparse",
                     schedule=Schedule(rounds=1, eval_every=1, mode="loop"),
                     **TINY)
    assert exp.strategy.capabilities.grad_exchange


def test_gossip_without_flat_form_is_dense_only(ba_world):
    """The derived layout restriction: a gossip strategy with no
    flat_aggregate form has only the padded-gather lowering, and the error
    names the surviving layouts."""
    from repro.engine.strategies import AggregationStrategy, register_method

    class _PaddedOnlyStrategy(AggregationStrategy):
        name = "padded-only"

        def aggregate(self, exp, state, params, gathered, mask):
            return params

    register_method("padded-only-test", _PaddedOnlyStrategy(),
                    overwrite=True)
    with pytest.raises(ValueError, match=r"flat_aggregate") as ei:
        Experiment(ba_world, "padded-only-test", layout="sparse")
    assert "('dense',)" in str(ei.value)
    # ...and the same strategy still constructs on the dense layout.
    Experiment(ba_world, "padded-only-test", layout="dense",
               schedule=Schedule(rounds=1, eval_every=1, mode="loop"),
               **TINY)


def test_declared_capability_layouts_drive_rejection(ba_world):
    """A strategy that declares layouts=('dense',) in its Capabilities
    record is rejected on sparse FROM the record — no string-matching on
    method names — and the message lists the supported layouts."""
    from repro.engine.strategies import (Capabilities, DecDiffStrategy,
                                         register_method)

    class _DenseDeclaredStrategy(DecDiffStrategy):
        name = "dense-declared"
        capabilities = Capabilities(layouts=("dense",))

    register_method("dense-declared-test", _DenseDeclaredStrategy(),
                    overwrite=True)
    with pytest.raises(ValueError, match="Capabilities record") as ei:
        Experiment(ba_world, "dense-declared-test", layout="sparse")
    assert "('dense',)" in str(ei.value)


def test_capabilities_layouts_validated():
    from repro.engine.strategies import Capabilities

    with pytest.raises(ValueError, match="non-empty subset"):
        Capabilities(layouts=())
    with pytest.raises(ValueError, match="non-empty subset"):
        Capabilities(layouts=("csr",))
    assert Capabilities(layouts=["sparse"]).layouts == ("sparse",)


def test_unknown_layout_rejected(ba_world):
    with pytest.raises(ValueError, match="unknown layout"):
        Experiment(ba_world, "decdiff", layout="csr")


def test_dense_layout_over_big_sparse_topology_refused():
    """layout='dense' forces densification, which the ≤4096-node oracle
    guard refuses at production node counts."""
    st = sparse_ring(4200)
    rng = np.random.default_rng(9)
    xs = [rng.normal(size=(1, 4)).astype(np.float32)] * 4200
    ys = [np.zeros(1, np.int32)] * 4200
    from repro.models.mlp_cnn import make_mlp
    world = World(model=make_mlp(num_classes=2, input_dim=4, hidden=(4,)),
                  topo=st, xs=xs, ys=ys,
                  x_test=rng.normal(size=(4, 4)).astype(np.float32),
                  y_test=np.zeros(4, np.int32))
    with pytest.raises(ValueError, match="refusing to densify"):
        Experiment(world, "decdiff", layout="dense")


def test_layout_inferred_from_topology_type(ba_world):
    exp = Experiment(ba_world, "decdiff",
                     schedule=Schedule(rounds=1, eval_every=1, mode="loop"),
                     **TINY)
    assert exp.layout == "sparse" and exp.sparse_plan is not None
    assert exp.nbr_idx is None
    dense = Experiment(ba_world, "decdiff", layout="dense",
                       schedule=Schedule(rounds=1, eval_every=1,
                                         mode="loop"), **TINY)
    assert dense.layout == "dense" and dense.sparse_plan is None
