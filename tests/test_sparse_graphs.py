"""SparseTopology: builders, CSR invariants, dense duality, and the
partition/padded-layout satellites (no hypothesis dependency — everything
here runs in tier-1)."""
import numpy as np
import pytest

from repro.graphs import (
    SparseTopology,
    make_sparse_topology,
    make_topology,
)
from repro.graphs import topology as topology_mod
from repro.graphs.partition import map_graph_to_pods, pod_adjacency
from repro.graphs.sparse import (
    _csr_connected,
    _pair_decode,
    sparse_barabasi_albert,
    sparse_complete,
    sparse_erdos_renyi,
    sparse_grid2d,
    sparse_ring,
    sparse_star,
    sparse_watts_strogatz,
)


def _assert_csr_invariants(st: SparseTopology):
    """Structural contract every SparseTopology must satisfy."""
    e = st.num_directed
    assert st.edge_src.dtype == np.int32 and st.edge_dst.dtype == np.int32
    assert st.edge_weight.dtype == np.float32
    assert st.row_offsets.dtype == np.int64
    assert st.row_offsets.shape == (st.num_nodes + 1,)
    assert st.row_offsets[0] == 0 and st.row_offsets[-1] == e
    assert (np.diff(st.row_offsets) >= 0).all()
    # sorted by (dst, src): dst non-decreasing, src ascending within a row
    assert (np.diff(st.edge_dst) >= 0).all()
    for i in range(st.num_nodes):
        row = st.edge_src[st.row_offsets[i]:st.row_offsets[i + 1]]
        assert (np.diff(row) > 0).all()  # strictly ascending, no dup edges
        assert (st.edge_dst[st.row_offsets[i]:st.row_offsets[i + 1]] == i).all()
    # no self loops; every directed edge has its reverse with equal weight
    assert (st.edge_src != st.edge_dst).all()
    fwd = {(int(s), int(d)): float(w)
           for s, d, w in zip(st.edge_src, st.edge_dst, st.edge_weight)}
    assert len(fwd) == e
    for (s, d), w in fwd.items():
        assert fwd[(d, s)] == w


SPARSE_CASES = [
    ("erdos_renyi", dict(n=40, p=0.25, seed=3)),
    ("barabasi_albert", dict(n=40, m=2, seed=0)),
    ("barabasi_albert", dict(n=40, m=1, seed=1)),  # hub-heavy tree
    ("watts_strogatz", dict(n=40, k=4, p=0.2, seed=0)),
    ("ring", dict(n=12)),
    ("star", dict(n=12)),
    ("complete", dict(n=9)),
    ("grid2d", dict(rows=3, cols=5)),
]


@pytest.mark.parametrize("name,kw", SPARSE_CASES,
                         ids=[f"{n}-{i}" for i, (n, _) in enumerate(SPARSE_CASES)])
def test_sparse_builder_invariants(name, kw):
    st = make_sparse_topology(name, **kw)
    _assert_csr_invariants(st)
    assert st.connected
    # connected flag agrees with a dense reachability check
    assert st.connected == topology_mod._is_connected(st.to_topology().adjacency)


def test_sparse_builders_deterministic():
    for name, kw in SPARSE_CASES:
        a = make_sparse_topology(name, **kw)
        b = make_sparse_topology(name, **kw)
        assert np.array_equal(a.edge_src, b.edge_src)
        assert np.array_equal(a.edge_dst, b.edge_dst)
        assert np.array_equal(a.edge_weight, b.edge_weight)


def test_round_trip_from_dense_bitwise():
    """from_topology -> to_topology reproduces the dense Topology bitwise,
    including non-unit float32 weights — the property that lets the dense
    engine act as the sparse engine's oracle."""
    for name, kw in [("erdos_renyi", dict(n=24, p=0.3, seed=1)),
                     ("barabasi_albert", dict(n=24, m=2, seed=0)),
                     ("star", dict(n=16)),
                     ("grid2d", dict(rows=4, cols=4))]:
        topo = make_topology(
            name, **kw, weight_fn=lambda i, j, rng: rng.uniform(0.1, 3.0))
        st = SparseTopology.from_topology(topo)
        _assert_csr_invariants(st)
        assert st.num_edges == topo.num_edges
        assert np.array_equal(st.degrees, topo.degrees)
        back = st.to_topology()
        assert np.array_equal(back.adjacency, topo.adjacency)
        assert np.array_equal(back.weights, topo.weights)
        assert np.array_equal(back.neighbor_idx, topo.neighbor_idx)
        assert np.array_equal(back.neighbor_mask, topo.neighbor_mask)
        assert back.max_degree == topo.max_degree
        assert back.connected == topo.connected


def test_sparse_dense_builders_same_structure():
    """Sparse ring/star/complete/grid2d are deterministic families — their
    edge sets must match the dense builders exactly."""
    pairs = [(sparse_ring(12), make_topology("ring", n=12)),
             (sparse_star(12), make_topology("star", n=12)),
             (sparse_complete(8), make_topology("complete", n=8)),
             (sparse_grid2d(3, 4), make_topology("grid2d", rows=3, cols=4))]
    for st, topo in pairs:
        ref = SparseTopology.from_topology(topo)
        assert np.array_equal(st.edge_src, ref.edge_src)
        assert np.array_equal(st.edge_dst, ref.edge_dst)
        assert np.array_equal(st.edge_weight, ref.edge_weight)
        assert np.array_equal(st.row_offsets, ref.row_offsets)


def test_from_pairs_dedupe_self_loops_first_wins():
    # pairs: (0,1) w=2, (1,0) dup w=9 (dropped, first wins), (2,2) self loop
    # (dropped), (1,2) w=5
    u = np.array([0, 1, 2, 1])
    v = np.array([1, 0, 2, 2])
    w = np.array([2.0, 9.0, 7.0, 5.0])
    st = SparseTopology.from_pairs("t", 3, u, v, weights=w)
    _assert_csr_invariants(st)
    assert st.num_edges == 2 and st.num_directed == 4
    fwd = {(int(s), int(d)): float(ww)
           for s, d, ww in zip(st.edge_src, st.edge_dst, st.edge_weight)}
    assert fwd == {(0, 1): 2.0, (1, 0): 2.0, (1, 2): 5.0, (2, 1): 5.0}


def test_pair_decode_inverts_triu_enumeration():
    for n in (2, 3, 7, 20):
        i_ref, j_ref = np.triu_indices(n, 1)
        codes = np.arange(n * (n - 1) // 2, dtype=np.int64)
        i, j = _pair_decode(n, codes)
        assert np.array_equal(i, i_ref) and np.array_equal(j, j_ref)


def test_csr_connected_detects_components():
    # two disjoint edges: {0,1} and {2,3}
    st = SparseTopology.from_pairs("d", 4, np.array([0, 2]), np.array([1, 3]))
    assert not st.connected
    assert not _csr_connected(st.num_nodes, st.row_offsets, st.edge_src)
    # isolated node 4 appended to a path
    st2 = SparseTopology.from_pairs("d2", 5, np.array([0, 1, 2]),
                                    np.array([1, 2, 3]))
    assert not st2.connected
    st3 = sparse_ring(5)
    assert st3.connected


def test_sparse_builder_error_paths():
    with pytest.raises(ValueError, match="1 <= m < n"):
        sparse_barabasi_albert(n=8, m=0)
    with pytest.raises(ValueError, match="1 <= m < n"):
        sparse_barabasi_albert(n=8, m=8)
    with pytest.raises(ValueError, match="even 0 < k < n"):
        sparse_watts_strogatz(n=8, k=3)
    with pytest.raises(ValueError, match="even 0 < k < n"):
        sparse_watts_strogatz(n=8, k=8)
    with pytest.raises(ValueError, match="unknown sparse topology"):
        make_sparse_topology("smallworldz", n=8)


def test_densify_guard():
    st = sparse_ring(4200)
    with pytest.raises(ValueError, match="refusing to densify"):
        st.to_topology()


def test_sparse_er_edge_count_tracks_p():
    """Exact G(n,p): realized edge count is Binomial(n(n-1)/2, p) — check
    it lands within 5 sigma for a mid-size graph."""
    n, p = 300, 0.1
    st = sparse_erdos_renyi(n=n, p=p, seed=0, ensure_connected=False)
    m_all = n * (n - 1) // 2
    mean, sd = m_all * p, np.sqrt(m_all * p * (1 - p))
    assert abs(st.num_edges - mean) < 5 * sd


def test_sparse_ba_scale_free_tail():
    """BA(m=2) should grow a hub: max degree well above the m=2 floor and
    above anything an ER graph of equal density produces typically."""
    st = sparse_barabasi_albert(n=2000, m=2, seed=0)
    assert st.max_degree > 30
    assert (st.degrees >= 1).all()


# ------------------------------------------------------- satellite: fallback


def test_ba_fallback_connected_without_networkx(monkeypatch):
    """Regression: the non-networkx BA fallback used to leave seed nodes
    rooting disjoint attachment trees (m=1 graphs could NEVER come out
    connected and the retry loop exhausted its 64 attempts).  With node m
    linked to seeds 0..m-1 the sample is connected by construction."""
    monkeypatch.setattr(topology_mod, "_HAVE_NX", False)
    for n, m, seed in [(8, 1, 0), (12, 1, 3), (16, 2, 0), (20, 3, 5)]:
        topo = topology_mod.barabasi_albert(n=n, m=m, seed=seed)
        assert topo.connected, (n, m, seed)
        assert (topo.adjacency == topo.adjacency.T).all()
        assert topo.adjacency.diagonal().sum() == 0
        # node m is linked to every seed node
        assert (topo.adjacency[m, :m] == 1).all()
        # attachment: every node past the seeds has at least one edge
        assert (topo.degrees >= 1).all()
    # even without the retry loop the construction is connected
    t = topology_mod.barabasi_albert(n=10, m=1, seed=7, ensure_connected=False)
    assert t.connected


# ------------------------------------------- satellite: _from_adjacency oracle


def _padded_reference(adj):
    """The O(N^2) per-row loop `_padded_neighbors` replaced."""
    n = adj.shape[0]
    degs = adj.sum(axis=1).astype(np.int64)
    max_deg = max(int(degs.max()), 1)
    nbr = -np.ones((n, max_deg), np.int32)
    msk = np.zeros((n, max_deg), np.int8)
    for i in range(n):
        cols = np.nonzero(adj[i])[0]
        nbr[i, :cols.size] = cols.astype(np.int32)
        msk[i, :cols.size] = 1
    return nbr, msk, max_deg


def test_from_adjacency_matches_loop_reference():
    """Golden pin: the vectorized padded layout is bit-identical to the
    naive per-row loop, on messy input (asymmetric, self loops, isolated
    rows) and with a weight_fn whose rng stream order must be preserved."""
    rng = np.random.default_rng(11)
    adj = (rng.random((23, 23)) < 0.2).astype(np.int8)
    np.fill_diagonal(adj, 1)  # _from_adjacency must zero these
    adj[5] = 0  # isolated-ish row (may still have in-edges symmetrized)
    topo = topology_mod._from_adjacency(
        "messy", adj.copy(),
        weight_fn=lambda i, j, r: r.uniform(0.5, 2.0),
        rng=np.random.default_rng(99))
    sym = np.maximum(adj, adj.T).astype(np.int8)
    np.fill_diagonal(sym, 0)
    assert np.array_equal(topo.adjacency, sym)
    nbr, msk, max_deg = _padded_reference(sym)
    assert np.array_equal(topo.neighbor_idx, nbr)
    assert np.array_equal(topo.neighbor_mask, msk)
    assert topo.max_degree == max_deg
    # weight stream: the upper-triangle order is part of the contract
    r = np.random.default_rng(99)
    ref_w = np.zeros((23, 23), np.float32)
    for i in range(23):
        for j in range(i + 1, 23):
            if sym[i, j]:
                w = float(r.uniform(0.5, 2.0))
                ref_w[i, j] = ref_w[j, i] = w
    assert np.array_equal(topo.weights, ref_w)


# --------------------------------------------------- satellite: partition


@pytest.mark.parametrize("name,kw,num_pods", [
    ("erdos_renyi", dict(n=20, p=0.3, seed=0), 4),
    ("erdos_renyi", dict(n=23, p=0.3, seed=1), 5),  # non-divisible
    ("barabasi_albert", dict(n=30, m=1, seed=2), 7),  # hub-heavy tree
    ("star", dict(n=17), 4),
    ("ring", dict(n=9), 9),  # one node per pod
    ("grid2d", dict(rows=4, cols=5), 3),
])
def test_map_graph_to_pods_exact_sizes(name, kw, num_pods):
    """Partition property: exact +-1 group sizes in the documented order
    (first n % p groups get the extra node), disjoint cover, no empties."""
    topo = make_topology(name, **kw)
    n = topo.num_nodes
    groups = map_graph_to_pods(topo, num_pods)
    assert len(groups) == num_pods
    base, rem = divmod(n, num_pods)
    assert [len(g) for g in groups] == \
        [base + 1 if g < rem else base for g in range(num_pods)]
    assert all(groups)  # no empty pods
    flat = sorted(x for g in groups for x in g)
    assert flat == list(range(n))


def test_map_graph_to_pods_rejects_bad_counts():
    topo = make_topology("ring", n=6)
    with pytest.raises(ValueError, match="num_pods must be >= 1"):
        map_graph_to_pods(topo, 0)
    with pytest.raises(ValueError, match="empty pods"):
        map_graph_to_pods(topo, 7)


# ------------------------------------------ satellite: hub-heavy coverage


def test_pod_adjacency_star_hub():
    """Star: every cut edge touches the hub's pod; quotient weights count
    each leaf edge once per direction."""
    topo = make_topology("star", n=16,
                        weight_fn=lambda i, j, rng: rng.uniform(0.1, 2.0))
    groups = map_graph_to_pods(topo, 4)
    w = pod_adjacency(topo, groups)
    assert w.shape == (4, 4)
    assert np.allclose(w, w.T)
    assert (np.diag(w) == 0).all()
    hub_pod = next(g for g, nodes in enumerate(groups) if 0 in nodes)
    # all inter-pod structure goes through the hub's pod
    off = w.copy()
    off[hub_pod, :] = 0
    off[:, hub_pod] = 0
    assert (off == 0).all()
    # total quotient weight = 2x the summed omega over cut (both directions)
    where = np.zeros(16, np.int64)
    for g, nodes in enumerate(groups):
        where[nodes] = g
    cut_w = sum(float(topo.weights[0, j]) for j in range(1, 16)
                if where[j] != hub_pod)
    assert np.isclose(w.sum(), 2 * cut_w)


def test_pod_adjacency_ba_tree():
    topo = make_topology("barabasi_albert", n=24, m=1, seed=0)
    groups = map_graph_to_pods(topo, 6)
    w = pod_adjacency(topo, groups)
    assert np.allclose(w, w.T) and (np.diag(w) == 0).all()
    # a connected graph's quotient over a partition keeps every pod reachable
    reach = topology_mod._is_connected((w > 0).astype(np.int8))
    assert reach


def test_neighbor_weights_hub_rows():
    """neighbor_weights() on hub-heavy graphs: hub row fully populated,
    leaf rows one entry, padding exactly zero."""
    for topo in (make_topology("star", n=10,
                               weight_fn=lambda i, j, rng: float(10 * i + j)),
                 make_topology("barabasi_albert", n=12, m=1, seed=1)):
        nw = topo.neighbor_weights()
        assert nw.shape == (topo.num_nodes, topo.max_degree)
        assert nw.dtype == np.float32
        for i in range(topo.num_nodes):
            d = int(topo.degrees[i])
            assert (nw[i, :d] > 0).all()
            assert (nw[i, d:] == 0).all()
            for k in range(d):
                j = int(topo.neighbor_idx[i, k])
                assert nw[i, k] == np.float32(topo.weights[i, j])
    star = make_topology("star", n=10,
                         weight_fn=lambda i, j, rng: float(10 * i + j))
    assert star.max_degree == 9
    # hub row carries weight w(0,j) = j for each leaf j (ascending order)
    assert np.array_equal(star.neighbor_weights()[0],
                          np.arange(1, 10, dtype=np.float32))


# --------------------------- satellite: hypothesis sampler property lanes
#
# Opt-in (`-m fuzz`, see conftest.py) and skipped entirely when hypothesis
# is absent — tier-1 stays dependency-free.  Each sampler property runs the
# full structural contract (`_assert_csr_invariants`) over RANDOM
# (n, param, seed) triples, not the fixed SPARSE_CASES grid.

try:
    from hypothesis import assume, given, settings
    from hypothesis import strategies as hst

    HAVE_HYP = True
except ImportError:  # pragma: no cover - hypothesis is optional
    HAVE_HYP = False


def _assert_same_edges(a: SparseTopology, b: SparseTopology):
    """Edge set, weights AND canonical (dst, src) ordering coincide."""
    assert a.num_nodes == b.num_nodes
    assert np.array_equal(a.edge_src, b.edge_src)
    assert np.array_equal(a.edge_dst, b.edge_dst)
    assert np.array_equal(a.edge_weight, b.edge_weight)
    assert np.array_equal(a.row_offsets, b.row_offsets)


if HAVE_HYP:

    SEEDS = hst.integers(min_value=0, max_value=2**31 - 1)

    @pytest.mark.fuzz
    @settings(deadline=None, max_examples=30)
    @given(n=hst.integers(3, 96), m=hst.integers(1, 4), seed=SEEDS)
    def test_fuzz_ba_sampler_invariants(n, m, seed):
        assume(m < n)
        st = sparse_barabasi_albert(n=n, m=m, seed=seed)
        _assert_csr_invariants(st)
        assert st.connected  # BA attachment is connected by construction
        assert (st.degrees >= 1).all()

    @pytest.mark.fuzz
    @settings(deadline=None, max_examples=30)
    @given(n=hst.integers(8, 64),
           p=hst.floats(0.2, 0.9, allow_nan=False),
           seed=SEEDS)
    def test_fuzz_er_sampler_invariants(n, p, seed):
        st = sparse_erdos_renyi(n=n, p=p, seed=seed)
        _assert_csr_invariants(st)
        assert st.connected  # ensure_connected resamples until it is

    @pytest.mark.fuzz
    @settings(deadline=None, max_examples=30)
    @given(n=hst.integers(3, 96), half_k=hst.integers(1, 3),
           p=hst.floats(0.0, 1.0, allow_nan=False), seed=SEEDS)
    def test_fuzz_ws_sampler_invariants(n, half_k, p, seed):
        k = 2 * half_k
        assume(k < n)
        st = sparse_watts_strogatz(n=n, k=k, p=p, seed=seed)
        _assert_csr_invariants(st)
        assert st.connected
        # rewiring never changes the edge COUNT, only endpoints
        assert st.num_edges == n * half_k

    @pytest.mark.fuzz
    @settings(deadline=None, max_examples=30)
    @given(n=hst.integers(2, 32), num_pairs=hst.integers(1, 200),
           seed=SEEDS)
    def test_fuzz_from_pairs_first_wins_idempotent(n, num_pairs, seed):
        """from_pairs on arbitrary (u, v, w) lists — self loops, duplicates,
        both orientations, conflicting weights: the structural contract
        holds, the FIRST weight of any duplicate wins, and feeding the
        resulting directed edge list back in is the identity."""
        rng = np.random.default_rng(seed)
        u = rng.integers(0, n, num_pairs)
        v = rng.integers(0, n, num_pairs)
        w = rng.uniform(0.1, 3.0, num_pairs).astype(np.float32)
        st = SparseTopology.from_pairs("fuzz", n, u, v, weights=w)
        _assert_csr_invariants(st)
        # first-wins: the stored weight is the FIRST input occurrence's
        first = {}
        for a, b, ww in zip(u, v, w):
            lo, hi = (int(a), int(b)) if a < b else (int(b), int(a))
            if lo != hi and (lo, hi) not in first:
                first[(lo, hi)] = np.float32(ww)
        got = {(min(int(s), int(d)), max(int(s), int(d))): np.float32(ww)
               for s, d, ww in zip(st.edge_src, st.edge_dst, st.edge_weight)}
        assert got == first
        # idempotence: the canonical directed list round-trips bitwise
        again = SparseTopology.from_pairs(
            "fuzz2", n, st.edge_src, st.edge_dst, weights=st.edge_weight)
        _assert_same_edges(st, again)

    @pytest.mark.fuzz
    @settings(deadline=None, max_examples=30)
    @given(case=hst.sampled_from(["erdos_renyi", "barabasi_albert",
                                  "watts_strogatz", "ring", "star"]),
           n=hst.integers(5, 256), seed=SEEDS)
    def test_fuzz_dense_round_trip(case, n, seed):
        """from_topology(to_topology(t)) is the identity on edge set,
        float32 weights and canonical ordering for every graph under the
        densify guard — the duality the oracle matrix rests on."""
        kw = {"erdos_renyi": dict(p=0.25, seed=seed),
              "barabasi_albert": dict(m=2, seed=seed),
              "watts_strogatz": dict(k=4, p=0.2, seed=seed),
              "ring": {}, "star": {}}[case]
        if case in ("barabasi_albert", "watts_strogatz"):
            assume(n > 4)
        st = make_sparse_topology(case, n=n, **kw)
        back = SparseTopology.from_topology(st.to_topology())
        _assert_same_edges(st, back)
        assert back.connected == st.connected
