"""Sparse scenario parity: the dense-oracle equivalence matrix.

The acceptance pin for lifting the sparse layout's three construction-time
carve-outs (dynamics, per-edge transport, CFA-GE): on ≤64-node worlds, at
participation=1.0, `Experiment(layout="sparse")` reproduces the dense
padded engine BIT-FOR-BIT — final params, total comm bytes, per-round
trigger history, and per-round live-edge history — across

  * methods     — the full strategy roster, under a dynamics process;
  * transports  — per-node triggered, per-edge fixed-threshold, per-edge
    adaptive int8 (stochastic rounding), with and without dynamics;
  * dynamics    — every shipped GraphProcess through the per-edge adaptive
    transport, scan-fused;
  * backends    — vmap and shard_map on both layouts (single-pod in tier-1,
    the forced 4-device mesh in the multihost lane).

Why bit-equality is possible at all: both layouts draw their dynamics coins
from ONE canonical uniform per undirected pair (ascending (lo, hi) order),
key their codecs by the canonical CSR directed-edge id, compose all masks
as products of exact {0,1} floats, and reduce through the same
`segment_neighbor_avg` kernel, which is invariant to row blocking and slot
padding.  participation < 1 is the documented exception (each layout draws
its own shape of uniforms), so the matrix runs at participation = 1.0.

The churn regression pins at the bottom mirror the PR-5 dense
reset-discrimination construction on the flat [E] edge bank: a dead edge
freezes its transport state bit-exactly, and a rejoin resets BOTH directed
records of every incident link (the `rev_edge` pair).

tests/.github lane note: the scale-smoke CI lane asserts this module
collects at least MATRIX_MIN_TESTS tests, so the matrix cannot silently
shrink.  Update the pin when deliberately extending the matrix.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig, SparseEdgeGossipTransport
from repro.dynamics import (
    EdgeDropout,
    GilbertElliott,
    GraphEvent,
    GraphProcess,
    NodeChurn,
    PeriodicRewiring,
    StaticGraph,
)
from repro.dynamics.processes import _live_layout
from repro.engine import Experiment, Schedule, World
from repro.graphs.sparse import rev_edge_permutation, sparse_ring

#: collection floor enforced by the CI scale-smoke lane (see .github).
MATRIX_MIN_TESTS = 26

TINY = dict(steps_per_round=1, batch_size=8, lr=0.1, momentum=0.9, seed=3)

CATALOG = [
    StaticGraph(),
    EdgeDropout(p=0.3),
    GilbertElliott(p_gb=0.25, p_bg=0.4),
    NodeChurn(p_leave=0.3, p_rejoin=0.6),
    PeriodicRewiring(period=2, num_graphs=3, seed=4,
                     topo_kwargs={"k": 2, "p": 0.2}),
]

ADAPTIVE = CommConfig(codec="int8", policy="adaptive", target_trigger=0.6,
                      per_edge=True)


@pytest.fixture(scope="module")
def ba_world():
    from repro.models.mlp_cnn import make_mlp

    return World.synthetic(dataset="synth-mnist", nodes=16,
                           topology="barabasi_albert", m=2, seed=5,
                           scale=0.02,
                           model=make_mlp(num_classes=10, hidden=(16,)))


def _run(world, method, layout, *, comm=None, dyn=None, backend="vmap",
         rounds=3, mode="loop"):
    w = world if dyn is None else dataclasses.replace(world, dynamics=dyn)
    exp = Experiment(w, method, comm=comm, backend=backend, layout=layout,
                     schedule=Schedule(rounds=rounds, eval_every=rounds,
                                       mode=mode), **TINY)
    exp.run()
    return exp


def _assert_bit_equal(a: Experiment, b: Experiment):
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    assert a.comm_bytes_total == b.comm_bytes_total
    assert a.trig_history == b.trig_history
    assert a.live_history == b.live_history


# ------------------------------------------------------------ method matrix


@pytest.mark.parametrize("method", ["decavg", "dechetero", "cfa", "cfa-ge",
                                    "decdiff", "decdiff+vt", "fedavg",
                                    "isol"])
def test_methods_match_dense_under_dropout(ba_world, method):
    """Every method in the roster, under EdgeDropout — including CFA-GE,
    whose gradient-exchange phase now lowers through the width buckets."""
    dyn = EdgeDropout(p=0.3)
    dense = _run(ba_world, method, "dense", dyn=dyn)
    sparse = _run(ba_world, method, "sparse", dyn=dyn)
    _assert_bit_equal(dense, sparse)


# --------------------------------------------------------- transport matrix


@pytest.mark.parametrize("dyn", [None, GilbertElliott(p_gb=0.25, p_bg=0.4)],
                         ids=["static", "gilbert-elliott"])
@pytest.mark.parametrize("comm", [
    CommConfig(codec="int8", trigger_threshold=0.5),
    CommConfig(codec="fp32", per_edge=True, trigger_threshold=0.5),
    ADAPTIVE,
], ids=["per-node-int8", "per-edge-fp32-thr", "per-edge-adaptive-int8"])
def test_transports_match_dense(ba_world, comm, dyn):
    """Per-node and per-edge transports: bytes, trigger history and the
    per-edge controller state all reproduce the dense oracle."""
    dense = _run(ba_world, "decdiff+vt", "dense", comm=comm, dyn=dyn)
    sparse = _run(ba_world, "decdiff+vt", "sparse", comm=comm, dyn=dyn)
    assert dense.comm_bytes_total > 0
    _assert_bit_equal(dense, sparse)


def test_per_edge_controller_state_matches_dense(ba_world):
    """Beyond the histories: the sparse [E] threshold/EMA/ever banks hold
    exactly the dense [N, max_deg] panels' valid entries, addressed by the
    canonical edge id (receiver CSR rows = dense slot order)."""
    dyn = EdgeDropout(p=0.3)
    dense = _run(ba_world, "decdiff+vt", "dense", comm=ADAPTIVE, dyn=dyn)
    sparse = _run(ba_world, "decdiff+vt", "sparse", comm=ADAPTIVE, dyn=dyn)
    st = sparse.topo
    off = st.row_offsets
    # dense slot d of row i is the OUT-link i -> nbr_idx[i, d]; its flat CSR
    # id is rev_edge[off[i] + d] (slot d of i's CSR row is the IN-link, and
    # rev_edge flips direction), so rev_edge[off[i]:off[i+1]] enumerates
    # dense row i's valid slots in order.
    rev = rev_edge_permutation(st)
    ds, ss = dense.comm_state, sparse.comm_state
    for name in ("last_sent", "threshold", "drift_ema", "ever_delivered"):
        panel = np.asarray(getattr(ds, name))
        flat = np.asarray(getattr(ss, name))
        for i in range(st.num_nodes):
            deg = off[i + 1] - off[i]
            ids = rev[off[i]:off[i + 1]]
            assert np.array_equal(panel[i, :deg], flat[ids]), (name, i)


# ---------------------------------------------------------- dynamics matrix


@pytest.mark.parametrize("dyn", CATALOG, ids=lambda p: p.name)
def test_processes_match_dense_through_adaptive_transport(ba_world, dyn):
    """Every shipped GraphProcess through the per-edge adaptive int8
    transport, scan-fused: live masks, resets, byte accounting and the
    controller all agree with the dense engine bit-for-bit."""
    dense = _run(ba_world, "decdiff+vt", "dense", comm=ADAPTIVE, dyn=dyn,
                 mode="fused")
    sparse = _run(ba_world, "decdiff+vt", "sparse", comm=ADAPTIVE, dyn=dyn,
                  mode="fused")
    _assert_bit_equal(dense, sparse)


# ----------------------------------------------------------- backend matrix


@pytest.mark.parametrize("method,comm", [
    ("decdiff+vt", ADAPTIVE),
    ("cfa-ge", None),
], ids=["per-edge-adaptive", "cfa-ge"])
def test_backends_match_across_layouts(ba_world, method, comm):
    """All four (layout, backend) combinations agree (single-pod mesh in
    tier-1; the real 4-pod split runs in the multihost lane below)."""
    dyn = NodeChurn(p_leave=0.25, p_rejoin=0.5)
    ref = _run(ba_world, method, "dense", comm=comm, dyn=dyn)
    for layout in ("dense", "sparse"):
        for backend in ("vmap", "shard_map"):
            exp = _run(ba_world, method, layout, comm=comm, dyn=dyn,
                       backend=backend)
            _assert_bit_equal(ref, exp)


@pytest.mark.multihost
@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs >= 4 devices for a real pod axis")
@pytest.mark.parametrize("method,comm", [
    ("decdiff+vt", ADAPTIVE),
    ("cfa-ge", None),
], ids=["per-edge-adaptive", "cfa-ge"])
def test_four_pod_mesh_matches_dense_vmap(ba_world, method, comm):
    """The forced 4-pod mesh: the sparse per-edge bank (replicated) and the
    bucketed CFA-GE walk lower blockwise and still match the dense vmap
    oracle bit-for-bit, scan-fused."""
    dyn = EdgeDropout(p=0.3)
    ref = _run(ba_world, method, "dense", comm=comm, dyn=dyn, mode="fused")
    sm = _run(ba_world, method, "sparse", comm=comm, dyn=dyn,
              backend="shard_map", mode="fused")
    assert int(sm.mesh.shape["pod"]) == 4
    _assert_bit_equal(ref, sm)


# ------------------------------------------- churn regression pins (PR-5
# reset-discrimination construction, re-run on the flat [E] edge bank)


@dataclasses.dataclass(frozen=True)
class ScriptedChurn(GraphProcess):
    """Test-only: alive follows a fixed [T, N] table; `_live_layout`
    realizes the live mask in whichever layout the topology carries, so ONE
    process definition drives both engines."""

    table: tuple  # T rows of N {0,1}

    name = "scripted_churn"
    needs_rng = False

    def init_state(self, topo):
        return jnp.ones((topo.num_nodes,), jnp.float32)

    def make_step(self, topo):
        _, _, from_alive = _live_layout(topo)
        table = jnp.asarray(self.table, jnp.float32)

        def step(prev_alive, round_idx, key):
            del key
            alive = table[round_idx % table.shape[0]]
            rejoined = (1.0 - prev_alive) * alive
            return alive, GraphEvent(live=from_alive(alive), alive=alive,
                                     rejoined=rejoined)

        return step


@pytest.fixture(scope="module")
def ring_world():
    from repro.models.mlp_cnn import make_mlp

    return World.synthetic(dataset="synth-mnist", nodes=4, topology="ring",
                           seed=3, scale=0.02,
                           model=make_mlp(num_classes=10, hidden=(32,)))


def _scripted(world):
    # 4-node ring; node 0: alive, dead, alive (rejoins at round 2)
    table = ((1, 1, 1, 1), (0, 1, 1, 1), (1, 1, 1, 1))
    return dataclasses.replace(world, dynamics=ScriptedChurn(table=table))


def test_rejoin_resets_both_directed_edge_records_in_engine(ring_world):
    """The dense pin (tests/test_dynamics.py::
    test_rejoin_resets_incident_edges_in_engine) on the sparse engine:
    with threshold 2.6 only zero references fire after bootstrap, so the
    round-2 fired edges are EXACTLY the 4 directed edges incident to the
    rejoined node — proving the engine raised reset on BOTH directed
    records (e and rev_edge[e]) of each incident link."""
    comm = CommConfig(codec="fp32", trigger_threshold=2.6, per_edge=True)
    exp = Experiment(_scripted(ring_world), "decdiff+vt", comm=comm,
                     layout="sparse",
                     schedule=Schedule(rounds=3, eval_every=3, mode="loop"),
                     steps_per_round=2, batch_size=16, lr=0.1, momentum=0.9,
                     seed=3)
    exp.run()
    assert exp.trig_history[0] == 1.0
    assert exp.trig_history[1] == 0.0
    assert abs(exp.trig_history[2] - 4.0 / 8.0) < 1e-6, exp.trig_history
    st = exp.topo
    rev = rev_edge_permutation(st)
    ever = np.asarray(exp.comm_state.ever_delivered)
    incident = np.flatnonzero((st.edge_src == 0) | (st.edge_dst == 0))
    # every incident link re-delivered in BOTH directions after the reset
    for e in incident:
        assert ever[e] == 1.0 and ever[rev[e]] == 1.0, e
    # ...and the engine's histories equal the dense engine's on the same
    # scripted world (the ScriptedChurn protocol is layout-agnostic).
    ref = Experiment(_scripted(ring_world), "decdiff+vt", comm=comm,
                     layout="dense",
                     schedule=Schedule(rounds=3, eval_every=3, mode="loop"),
                     steps_per_round=2, batch_size=16, lr=0.1, momentum=0.9,
                     seed=3)
    ref.run()
    _assert_bit_equal(ref, exp)


def test_dead_edge_freezes_sparse_transport_state():
    """Direct transport API (the dense pin's [E] mirror): a reset returns
    exactly the flagged edges to bootstrap — including the reverse-direction
    record — and a live=0 edge advances NOTHING: reference, residual,
    threshold, EMA and delivery history all stay bit-identical."""
    st = sparse_ring(4)
    params = {"w": jnp.asarray(np.random.default_rng(0)
                               .standard_normal((4, 16)), jnp.float32)}
    cfg = CommConfig(codec="int8", policy="adaptive", target_trigger=0.9,
                     stochastic=False)
    tr = SparseEdgeGossipTransport(cfg, params, st)
    state = tr.init_state(params)
    link = jnp.ones((st.num_directed,), jnp.float32)
    for _ in range(3):  # advance thresholds/EMA/references
        _, _, _, state = tr.exchange(params, state, link)
    rej = jnp.zeros((4,), jnp.float32).at[0].set(1.0)
    reset = jnp.maximum(rej[tr.edge_src], rej[tr.edge_dst])
    state2 = tr.reset_edges(state, reset)
    rmask = np.asarray(reset) > 0
    rev = np.asarray(tr.rev_edge)
    assert rmask[rev[rmask]].all()  # the reset set is rev_edge-closed
    assert (np.asarray(state2.last_sent)[rmask] == 0).all()
    assert (np.asarray(state2.threshold)[rmask] == tr.thr0).all()
    assert (np.asarray(state2.drift_ema)[rmask] == 0).all()
    assert (np.asarray(state2.ever_delivered)[rmask] == 0).all()
    for f, f2 in zip(state, state2):  # untouched edges bit-identical
        if f is not None:
            assert np.array_equal(np.asarray(f)[~rmask],
                                  np.asarray(f2)[~rmask])
    # frozen-when-down: a live=0 edge advances nothing
    live = 1.0 - reset
    _, _, gate, state3 = tr.exchange(params, state2, link * live, live=live)
    assert (np.asarray(gate)[rmask] == 0).all()
    for f2, f3 in zip(state2, state3):
        if f2 is not None:
            assert np.array_equal(np.asarray(f2)[rmask],
                                  np.asarray(f3)[rmask])
