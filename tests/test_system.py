"""End-to-end behaviour tests: the decentralized learning system reproduces
the paper's qualitative claims at miniature scale (fast CPU settings).

Runs go through the `repro.engine.Experiment` front door (the scan-fused
default schedule), which tests/test_engine.py pins as bit-identical to the
legacy per-round loop."""
import numpy as np
import pytest

from repro.data import make_dataset, zipf_allocation
from repro.data.allocation import split_by_allocation
from repro.engine import Experiment, Schedule, World
from repro.fl.metrics import characteristic_time, comm_bytes_per_round
from repro.graphs import make_topology
from repro.models.mlp_cnn import make_mlp, model_for_dataset
from repro.utils.pytree import tree_bytes


@pytest.fixture(scope="module")
def tiny_world():
    ds = make_dataset("synth-mnist", seed=0, scale=0.03)
    topo = make_topology("erdos_renyi", n=8, p=0.4, seed=1)
    alloc = zipf_allocation(ds.y_train, 8, seed=1, min_per_class=1)
    xs, ys = split_by_allocation(ds.x_train, ds.y_train, alloc)
    model = make_mlp(num_classes=10, hidden=(64, 32))
    return ds, topo, xs, ys, model


def _world(tiny_world) -> World:
    ds, topo, xs, ys, model = tiny_world
    return World(model=model, topo=topo, xs=xs, ys=ys,
                 x_test=ds.x_test, y_test=ds.y_test)


def _run(tiny_world, method, rounds=12, **kw):
    exp = Experiment(_world(tiny_world), method,
                     schedule=Schedule(rounds=rounds, eval_every=3),
                     steps_per_round=4, batch_size=32, lr=0.1, momentum=0.9,
                     seed=0, **kw)
    return exp.run()


def test_decdiff_vt_learns(tiny_world):
    hist = _run(tiny_world, "decdiff+vt", rounds=15)
    assert hist[-1].acc_mean > 0.3  # far above 10% chance
    assert hist[-1].acc_mean > hist[0].acc_mean + 0.1


def test_dechetero_disruption_at_first_aggregation(tiny_world):
    """Paper Fig. 1: with heterogeneous inits, plain averaging destroys the
    models right after the first exchange, unlike DecDiff."""
    results = {}
    for method in ("dechetero", "decdiff+vt"):
        exp = Experiment(_world(tiny_world), method,
                         schedule=Schedule(rounds=2, eval_every=1),
                         steps_per_round=8, batch_size=32, lr=0.1,
                         momentum=0.9, seed=0)
        hist = exp.run()
        results[method] = [m.acc_mean for m in hist]
    drop_hetero = results["dechetero"][0] - results["dechetero"][1]
    drop_decdiff = results["decdiff+vt"][0] - results["decdiff+vt"][1]
    assert drop_decdiff < drop_hetero + 0.02  # DecDiff at least as stable


def test_isolation_no_communication(tiny_world):
    ds, topo, xs, ys, model = tiny_world
    hist = _run(tiny_world, "isol", rounds=6)
    assert len(hist) > 0  # runs fine with zero exchange
    params = model.init(__import__("jax").random.PRNGKey(0))
    assert comm_bytes_per_round("isol", topo, tree_bytes(params)) == 0


def test_comm_cost_ordering(tiny_world):
    """Paper §VI: CFA-GE moves ~4x the bytes of model-only methods; FedAvg
    scales with nodes not edges."""
    _, topo, _, _, model = tiny_world
    mb = tree_bytes(model.init(__import__("jax").random.PRNGKey(0)))
    plain = comm_bytes_per_round("decdiff+vt", topo, mb)
    cfa_ge = comm_bytes_per_round("cfa-ge", topo, mb)
    fed = comm_bytes_per_round("fedavg", topo, mb)
    assert cfa_ge == 4 * plain
    assert fed == 2 * topo.num_nodes * mb
    assert plain == 2 * topo.num_edges * mb


def test_fedavg_keeps_models_identical(tiny_world):
    exp = Experiment(_world(tiny_world), "fedavg",
                     schedule=Schedule(rounds=2, eval_every=1),
                     steps_per_round=2, batch_size=16, lr=0.05, momentum=0.5)
    exp.run()
    import jax

    leaves = jax.tree.leaves(exp.params)
    for leaf in leaves:
        arr = np.asarray(leaf, np.float32)
        assert np.allclose(arr, arr[:1], atol=1e-6)  # all nodes share params


def test_characteristic_time():
    from repro.fl.metrics import RoundMetrics

    hist = [RoundMetrics(r, np.full(3, a), np.zeros(3))
            for r, a in [(0, 0.2), (5, 0.5), (10, 0.8), (15, 0.96)]]
    ct = characteristic_time(hist, centralized_acc=1.0)
    assert ct[0.5] == 5 and ct[0.8] == 10 and ct[0.95] == 15


def test_partial_participation_runs(tiny_world):
    hist = _run(tiny_world, "decdiff+vt", rounds=4, participation=0.5)
    assert np.isfinite(hist[-1].acc_mean)


def test_cfa_ge_runs(tiny_world):
    hist = _run(tiny_world, "cfa-ge", rounds=4)
    assert np.isfinite(hist[-1].acc_mean)


def test_model_for_dataset_mapping():
    assert model_for_dataset("synth-mnist", 10).name == "mlp"
    assert model_for_dataset("synth-fashion", 10).name == "cnn"
    assert model_for_dataset("synth-emnist", 26).name == "cnn"


def test_heterogeneous_local_epochs(tiny_world):
    """Paper Alg. 1: E may differ per node — runs and still learns."""
    hist = _run(tiny_world, "decdiff+vt", rounds=6, hetero_steps_min=1)
    assert np.isfinite(hist[-1].acc_mean)
    assert hist[-1].acc_mean >= hist[0].acc_mean - 0.05


def test_dataset_generation_is_process_deterministic():
    """Pin the (name, seed) determinism contract of make_dataset: the seed
    used to be derived from Python's per-process-randomized hash(), so every
    process silently got a different dataset, making 'seeded' regression
    numbers unreproducible across runs.  The label stream is pure RNG (no
    BLAS), so its digest is stable across platforms."""
    import hashlib

    ds = make_dataset("synth-mnist", seed=0, scale=0.03)
    y_tr = hashlib.md5(np.asarray(ds.y_train, np.int32).tobytes()).hexdigest()
    y_te = hashlib.md5(np.asarray(ds.y_test, np.int32).tobytes()).hexdigest()
    assert y_tr == "53642f646512557ef6c202fd4361e5c1"
    assert y_te == "943a07b7cca1c7b0b34cebb1ff5f353f"
    # image path crosses BLAS (einsum): pin loosely, not bitwise
    np.testing.assert_allclose(float(ds.x_train[0, 0, 0]), -1.2846653, rtol=1e-5)


@pytest.fixture(scope="module")
def ba_world():
    """The comm smoke config: 8-node Barabási–Albert scale-free graph over
    the reduced synth-mnist world — imported from bench_comm so this tier-1
    regression pins the SAME seeded world the BENCH_comm.json acceptance
    gate measures."""
    from benchmarks.bench_comm import smoke_world

    return smoke_world()


def _run_comm(ba_world, comm, rounds=15):
    from repro.fl import CommConfig  # noqa: F401 (re-export sanity)

    exp = Experiment(_world(ba_world), "decdiff+vt", comm=comm,
                     schedule=Schedule(rounds=rounds, eval_every=5),
                     steps_per_round=4, batch_size=32, lr=0.1, momentum=0.9,
                     seed=0)
    hist = exp.run()
    return exp, hist


def test_int8_event_triggered_matches_dense_at_2x_fewer_bytes(ba_world):
    """The paper's headline claim, pinned as a seeded tier-1 regression:
    int8 + event-triggered DecDiff+VT on the 8-node BA smoke stays within
    tolerance of dense (free-communication-priced) accuracy while moving
    >= 2x fewer bytes on the wire."""
    from repro.comm import CommConfig

    dense_sim, dense_hist = _run_comm(
        ba_world, CommConfig(codec="fp32", trigger_threshold=0.0))
    comp_sim, comp_hist = _run_comm(
        ba_world, CommConfig(codec="int8", trigger_threshold=1.0))

    dense_acc = dense_hist[-1].acc_mean
    comp_acc = comp_hist[-1].acc_mean
    assert dense_acc > 0.4  # the dense smoke actually learns
    assert comp_acc > dense_acc - 0.03  # compression does not break learning
    # >= 2x bytes-on-wire reduction (int8 alone is ~4x; the trigger adds more)
    assert 2 * comp_sim.comm_bytes_total <= dense_sim.comm_bytes_total
    # the drift trigger genuinely gated transmissions (not degenerate 0 or 1)
    assert 0.3 < comp_hist[-1].triggered_frac < 1.0
    # dense accounting matches the static always-send formula
    ds, topo, xs, ys, model = ba_world
    model_bytes = tree_bytes(model.init(__import__("jax").random.PRNGKey(0)))
    rounds = 15
    assert dense_sim.comm_bytes_total == comm_bytes_per_round(
        "decdiff+vt", topo, model_bytes) * rounds
    assert dense_hist[-1].bytes_on_wire == dense_sim.comm_bytes_total
