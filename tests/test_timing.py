"""repro.timing contracts: the event clock, its models, and its threading
through the engine.

The load-bearing pins:

  1. degeneracy — `timing=Timing()` with no deadline (zero latency,
     infinite bandwidth, uniform unit step time) is BIT-IDENTICAL to
     `timing=None` — params, bytes, trigger and live histories — across
     methods × transports × backends × layouts × schedule modes (timing
     consumes no rng by construction, so the streams cannot diverge);
  2. arithmetic — the clock is exact: synchronous ticks are the realized
     makespan (slowest node, stretched to the slowest live link's landing
     time when the round exchanges), deadline ticks are exactly
     `deadline`, and `floor(deadline / dt)` caps the local step budget;
  3. lateness — a payload that misses the deadline IS a failed link: the
     per-node stale path masks it via `ever_recv` (delivery history, NOT
     `ever_sent` — the regression this PR fixes), bytes are still burned,
     and making both directions of a pair permanently late is bit-identical
     to scripting that pair out of the graph;
  4. processes — ScriptedGraph replays its mask tables (wrap/clamp) the
     same on both layouts; EnergyChurn integrates the clock's realized
     per-node cost exactly and refuses to run without a Timing;
  5. schedule — loop and scan-fused stay bit-identical with the clock as
     carried state, and the fused program still lowers to ONE lax.scan.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig
from repro.dynamics import EnergyChurn, NodeChurn, ScriptedGraph
from repro.engine import Experiment, Schedule, World
from repro.timing import (
    ConstantLink,
    ConstantStep,
    LognormalLink,
    LognormalStep,
    StragglerStep,
    TableLink,
    Timing,
    TimingState,
    TraceStep,
    make_link_model,
    make_node_model,
)

TINY = dict(steps_per_round=4, batch_size=16, lr=0.1, momentum=0.9, seed=3)

# heterogeneous models used whenever the test only needs "some" timing
HET = Timing(node=LognormalStep(sigma=0.5, seed=7),
             link=LognormalLink(seed=9))


@pytest.fixture(scope="module")
def ba_world():
    from repro.models.mlp_cnn import make_mlp

    return World.synthetic(dataset="synth-mnist", nodes=16,
                           topology="barabasi_albert", m=2, seed=3,
                           scale=0.02,
                           model=make_mlp(num_classes=10, hidden=(32,)))


@pytest.fixture(scope="module")
def ring_world():
    from repro.models.mlp_cnn import make_mlp

    return World.synthetic(dataset="synth-mnist", nodes=4, topology="ring",
                           seed=3, scale=0.02,
                           model=make_mlp(num_classes=10, hidden=(32,)))


def _params_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _with(world, **kw):
    return dataclasses.replace(world, **kw)


def _run(world, method="decdiff+vt", rounds=3, **kw):
    args = dict(TINY)
    args.update(kw)
    sched = args.pop("schedule", Schedule(rounds=rounds, eval_every=rounds))
    exp = Experiment(world, method, schedule=sched, **args)
    exp.run()
    return exp


# --------------------------------------------------- 1. degeneracy oracle

def _fingerprint(exp):
    return (tuple(exp.trig_history), exp.comm_bytes_total,
            tuple(exp.live_history))


@pytest.mark.parametrize("mode", ["loop", "fused"])
@pytest.mark.parametrize("layout", ["dense", "sparse"])
@pytest.mark.parametrize("backend", ["vmap", "shard_map"])
def test_degenerate_timing_bit_identical_matrix(ba_world, backend, layout,
                                                mode):
    """Timing() + no deadline == timing=None, bit for bit, on the full
    backend × layout × schedule-mode matrix (16-node BA, per-node int8
    event-triggered transport so the silence path is exercised too)."""
    comm = CommConfig(codec="int8", trigger_threshold=0.3)
    sched = Schedule(rounds=3, eval_every=3, mode=mode)
    ref = _run(ba_world, comm=comm, backend=backend, layout=layout,
               schedule=sched)
    tim = _run(_with(ba_world, timing=Timing()), comm=comm, backend=backend,
               layout=layout, schedule=sched)
    assert _params_equal(ref.params, tim.params)
    assert _fingerprint(ref) == _fingerprint(tim)
    # the degenerate clock still reports: unit step time, B steps/round
    assert tim.sim_time == 3 * TINY["steps_per_round"]
    assert tim.arrived_history == [1.0, 1.0, 1.0]


@pytest.mark.parametrize("method,comm", [
    ("decavg", None),
    ("cfa", None),
    ("cfa-ge", None),          # transport-free (grad-exchange capability)
    ("isol", None),
    ("fedavg", None),
    ("decavg", CommConfig(codec="fp32")),
    ("decdiff+vt", CommConfig(codec="fp32")),
    ("decdiff+vt", CommConfig(codec="int8", trigger_threshold=0.3)),
    ("decdiff+vt", CommConfig(codec="int8", policy="adaptive",
                              target_trigger=0.7, per_edge=True)),
    ("cfa", CommConfig(codec="int8", trigger_threshold=0.3,
                       per_edge=True)),
])
def test_degenerate_timing_bit_identical_methods(ring_world, method, comm):
    """The same oracle across the strategy roster × transport roster."""
    ref = _run(ring_world, method, comm=comm)
    tim = _run(_with(ring_world, timing=Timing()), method, comm=comm)
    assert _params_equal(ref.params, tim.params)
    assert _fingerprint(ref) == _fingerprint(tim)


def test_degenerate_timing_bit_identical_with_dynamics(ring_world):
    """...and composed with a stochastic GraphProcess: the clock consumes
    no rng, so churn realizes identically with and without it."""
    dyn = NodeChurn(p_leave=0.3, p_rejoin=0.6)
    comm = CommConfig(codec="fp32", trigger_threshold=0.3)
    ref = _run(_with(ring_world, dynamics=dyn), comm=comm, rounds=4)
    tim = _run(_with(ring_world, dynamics=dyn, timing=Timing()), comm=comm,
               rounds=4)
    assert _params_equal(ref.params, tim.params)
    assert _fingerprint(ref) == _fingerprint(tim)


# ------------------------------------------------------ 2. clock arithmetic

def test_sync_makespan_is_exact(ring_world):
    """ConstantStep(dt) with a zero-cost link: every synchronous tick is
    exactly B * dt; a nonzero link latency stretches it by the landing
    time; a non-exchanging method (isol) pays compute only."""
    w = _with(ring_world, timing=Timing(node=ConstantStep(2.0)))
    assert _run(w, rounds=3).sim_time == 3 * 4 * 2.0
    w = _with(ring_world, timing=Timing(node=ConstantStep(2.0),
                                        link=ConstantLink(latency=1.5)))
    assert _run(w, rounds=3).sim_time == 3 * (4 * 2.0 + 1.5)
    assert _run(w, "isol", rounds=3).sim_time == 3 * 4 * 2.0


def test_straggler_dominates_sync_makespan(ring_world):
    """StragglerStep: the slow minority sets the synchronous clock."""
    st = StragglerStep(dt=1.0, frac=0.25, factor=8.0, seed=3)
    assert (list(st.slow_nodes(4))
            == [int(np.argmax(np.asarray(st.bind(4)(jnp.int32(0)))))])
    exp = _run(_with(ring_world, timing=Timing(node=st)), rounds=2)
    assert exp.sim_time == 2 * 4 * 8.0


def test_deadline_caps_local_steps_and_ticks(ring_world):
    """Schedule(deadline=2.5) under unit step time: every node trains
    floor(2.5) = 2 of its 4 budgeted steps, the realized per-node cost is
    2.0s, and the clock ticks by exactly the deadline."""
    exp = _run(_with(ring_world, timing=Timing()),
               schedule=Schedule(rounds=3, eval_every=3, deadline=2.5))
    assert exp.sim_time == 3 * 2.5
    assert exp.sim_time_history == [2.5, 5.0, 7.5]
    assert np.asarray(exp.time_state.last_cost).tolist() == [2.0] * 4
    assert exp.arrived_history == [1.0, 1.0, 1.0]


def test_deadline_requires_timing(ring_world):
    with pytest.raises(ValueError, match="needs World\\(timing"):
        Experiment(ring_world, "decdiff+vt",
                   schedule=Schedule(deadline=1.0), **TINY)
    with pytest.raises(ValueError, match="deadline"):
        Schedule(deadline=-1.0)


def test_world_rejects_non_timing(ring_world):
    with pytest.raises(TypeError, match="repro.timing.Timing"):
        Experiment(_with(ring_world, timing=ConstantStep()), "decdiff+vt",
                   **TINY)


# ------------------------------------------------- 3. lateness = link down

def _late_pair_latency(topo, pairs):
    """Canonical [num_directed] latency table: 1e9 on both directions of
    each (lo, hi) pair, 0 elsewhere."""
    if hasattr(topo, "edge_src"):
        src = np.asarray(topo.edge_src)
        dst = np.asarray(topo.edge_dst)
    else:
        dst, src = np.nonzero(topo.adjacency)
    lat = np.zeros(len(src), np.float32)
    for lo, hi in pairs:
        lat[((src == lo) & (dst == hi)) | ((src == hi) & (dst == lo))] = 1e9
    return lat, src, dst


@pytest.mark.parametrize("layout", ["dense", "sparse"])
def test_late_edge_is_stale_and_never_recv(ring_world, layout):
    """The silence-path regression pin (on both layouts): a sender whose
    payloads NEVER arrive must not be aggregated under on_silence=stale.
    `ever_sent` flips on send; `ever_recv` — what the stale mask now
    consults — must not.  The aggregation outcome is pinned bit-exactly
    against a world where the late pair simply does not exist."""
    topo = ring_world.topo  # both layouts share the canonical edge order
    lat, src, dst = _late_pair_latency(topo, [(0, 1)])
    late = lat > 0
    tm = Timing(link=TableLink(latency=lat))
    sched = Schedule(rounds=3, eval_every=3, deadline=10.0)
    exp = _run(_with(ring_world, timing=tm), layout=layout,
               comm=CommConfig(codec="fp32", on_silence="stale"),
               schedule=sched)
    st = exp.comm_state
    # everyone transmitted every round (threshold 0)...
    assert np.asarray(st.ever_sent).min() == 1.0
    if layout == "sparse":
        ever = np.asarray(st.ever_recv)
    else:
        # scatter the [N, max_deg] panel to canonical directed-edge order:
        # panel slot e of receiver r is the e-th of r's sender-ascending
        # in-edges — the canonical (dst, src) order restricted to dst == r.
        panel = np.asarray(st.ever_recv)
        slot = np.concatenate([np.arange(np.sum(dst == r))
                               for r in range(topo.num_nodes)])
        ever = panel[dst, slot]
    # ...but the late pair never DELIVERED, everyone else did
    assert (ever[late] == 0.0).all()
    assert (ever[~late] == 1.0).all()
    # bit-identical to the same schedule with the pair scripted out
    cut = np.array(topo.adjacency, np.float32)
    cut[0, 1] = cut[1, 0] = 0.0
    ref = _run(_with(ring_world, timing=Timing(),
                     dynamics=ScriptedGraph(tables=cut[None])),
               layout=layout,
               comm=CommConfig(codec="fp32", on_silence="stale"),
               schedule=sched)
    assert _params_equal(exp.params, ref.params)
    # lateness burns the sender's bytes; a non-existent link carries none
    assert exp.comm_bytes_total > ref.comm_bytes_total


def test_late_edge_arrival_accounting(ring_world):
    """arrived_frac counts exactly the on-time directed edges."""
    lat, _, _ = _late_pair_latency(ring_world.topo, [(0, 1)])
    exp = _run(_with(ring_world, timing=Timing(link=TableLink(latency=lat))),
               schedule=Schedule(rounds=2, eval_every=2, deadline=10.0))
    assert exp.arrived_history == [6.0 / 8.0] * 2


def test_drop_mode_masks_late_edges_too(ring_world):
    """on_silence=drop with one late pair: the late slots carry zero
    aggregation weight but bytes are still burned (same totals as stale —
    byte accounting is sender-side)."""
    lat, _, _ = _late_pair_latency(ring_world.topo, [(0, 1)])
    tm = Timing(link=TableLink(latency=lat))
    sched = Schedule(rounds=3, eval_every=3, deadline=10.0)
    a = _run(_with(ring_world, timing=tm),
             comm=CommConfig(codec="fp32", on_silence="drop"), schedule=sched)
    b = _run(_with(ring_world, timing=tm),
             comm=CommConfig(codec="fp32", on_silence="stale"), schedule=sched)
    assert a.comm_bytes_total == b.comm_bytes_total
    # with threshold 0 every on-time edge re-delivers each round, so stale
    # and drop see identical masks and agree bit-exactly
    assert _params_equal(a.params, b.params)


def test_per_edge_transport_freezes_late_links(ring_world):
    """Per-edge transport: a late link is a failed link — the receiver's
    cache freezes (`ever_delivered` stays 0 on the late pair)."""
    lat, src, dst = _late_pair_latency(ring_world.topo, [(0, 1)])
    exp = _run(_with(ring_world, timing=Timing(link=TableLink(latency=lat))),
               comm=CommConfig(codec="fp32", per_edge=True),
               schedule=Schedule(rounds=3, eval_every=3, deadline=10.0))
    panel = np.asarray(exp.comm_state.ever_delivered)  # [N, max_deg]
    slot = np.concatenate([np.arange(np.sum(dst == r)) for r in range(4)])
    ever = panel[dst, slot]
    assert (ever[lat > 0] == 0.0).all()
    assert (ever[lat == 0] == 1.0).all()


# ------------------------------------------------------------ 4. processes

def test_scripted_graph_wrap_and_clamp(ring_world):
    """A [2, N, N] table under both past-end rules: wrap replays 0,1,0,1...;
    clamp holds the last row."""
    n = 4
    full = np.zeros((n, n), np.float32)
    idx = np.arange(n)
    full[idx, (idx + 1) % n] = full[(idx + 1) % n, idx] = 1.0
    half = np.array(full)
    half[0, 1] = half[1, 0] = half[2, 3] = half[3, 2] = 0.0
    tables = np.stack([full, half])
    for rule, want in [("wrap", [1.0, 0.5, 1.0, 0.5]),
                       ("clamp", [1.0, 0.5, 0.5, 0.5])]:
        exp = _run(_with(ring_world,
                         dynamics=ScriptedGraph(tables=tables,
                                                past_end=rule)), rounds=4)
        assert exp.live_history == want, rule


def test_scripted_graph_dense_sparse_parity(ring_world):
    """The same table replays identically on both layouts (params + live
    history), like every other GraphProcess."""
    n = 4
    full = np.zeros((n, n), np.float32)
    idx = np.arange(n)
    full[idx, (idx + 1) % n] = full[(idx + 1) % n, idx] = 1.0
    half = np.array(full)
    half[0, 1] = half[1, 0] = 0.0
    dyn = ScriptedGraph(tables=np.stack([full, half]))
    runs = {lay: _run(_with(ring_world, dynamics=dyn), layout=lay, rounds=4)
            for lay in ("dense", "sparse")}
    assert _params_equal(runs["dense"].params, runs["sparse"].params)
    assert runs["dense"].live_history == runs["sparse"].live_history


def test_scripted_graph_validation():
    with pytest.raises(ValueError, match="past_end"):
        ScriptedGraph(tables=np.ones((1, 2, 2)), past_end="loop")
    with pytest.raises(ValueError, match="\\{0, 1\\}"):
        ScriptedGraph(tables=np.full((1, 2, 2), 0.5))
    with pytest.raises(ValueError, match="square"):
        ScriptedGraph(tables=np.ones((1, 2, 3)))
    asym = np.zeros((1, 3, 3), np.float32)
    asym[0, 0, 1] = 1.0
    sg = ScriptedGraph(tables=asym)
    from repro.graphs import make_topology
    with pytest.raises(ValueError, match="symmetric"):
        sg.bind(make_topology("ring", n=3))


def test_energy_churn_integrates_realized_cost(ring_world):
    """EnergyChurn under ConstantStep(1.0), B=4 (realized cost 4.0/round
    while alive, observed one round late): capacity 9, recharge 3,
    rejoin_at 4 gives the exact schedule
      r0: obs=0  e=9  alive     r3: obs=4  e=clip(1-4)=0  dies
      r1: obs=4  e=5  alive     r4: e=0+3=3 < 4           dead
      r2: obs=4  e=1  alive     r5: e=3+3=6 >= 4          rejoins
    The rejoin round itself recharges (the transition runs BEFORE
    training), so the final energy is 6 — the drain for its 4 trained
    steps would land at a round 6 that never runs."""
    dyn = EnergyChurn(capacity=9.0, recharge=3.0, rejoin_at=4.0)
    exp = _run(_with(ring_world, timing=Timing(), dynamics=dyn), rounds=6)
    assert exp.live_history == [1.0, 1.0, 1.0, 0.0, 0.0, 1.0]
    energy, alive = exp.dyn_state
    assert np.asarray(alive).tolist() == [1.0] * 4
    assert np.asarray(energy).tolist() == [6.0] * 4
    # the clock only billed the alive rounds: 4 alive rounds x 4 steps
    assert exp.sim_time == 4 * 4.0
    assert np.asarray(exp.time_state.last_cost).tolist() == [4.0] * 4


def test_energy_churn_requires_timing(ring_world):
    with pytest.raises(ValueError, match="observes the event clock"):
        Experiment(_with(ring_world, dynamics=EnergyChurn()), "decdiff+vt",
                   **TINY)


def test_energy_churn_validation():
    with pytest.raises(ValueError, match="capacity"):
        EnergyChurn(capacity=0.0)
    with pytest.raises(ValueError, match="rejoin_at"):
        EnergyChurn(capacity=4.0, rejoin_at=5.0)


# --------------------------------------------------------------- 5. models

def test_trace_step_wrap_and_clamp():
    table = np.array([[1.0, 2.0], [3.0, 4.0]], np.float32)
    wrap = TraceStep(table=table).bind(2)
    clamp = TraceStep(table=table, past_end="clamp").bind(2)
    assert np.asarray(wrap(jnp.int32(4))).tolist() == [1.0, 2.0]
    assert np.asarray(clamp(jnp.int32(4))).tolist() == [3.0, 4.0]
    with pytest.raises(ValueError, match="positive"):
        TraceStep(table=np.zeros((2, 2)))
    with pytest.raises(ValueError, match="2 nodes"):
        TraceStep(table=table).bind(3)


def test_lognormal_models_deterministic_by_seed(ring_world):
    a = LognormalStep(sigma=0.5, seed=7).bind(8)(0)
    b = LognormalStep(sigma=0.5, seed=7).bind(8)(5)
    assert np.array_equal(np.asarray(a), np.asarray(b))  # static per node
    c = LognormalStep(sigma=0.5, seed=8).bind(8)(0)
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    topo = ring_world.topo
    t1 = LognormalLink(seed=9).bind(topo, 100.0)
    t2 = LognormalLink(seed=9).bind(topo, 100.0)
    assert np.array_equal(t1, t2)
    # per-PAIR draws: both directions of a link price identically
    lat, src, dst = _late_pair_latency(topo, [])
    fwd = (src == 0) & (dst == 1)
    rev = (src == 1) & (dst == 0)
    assert t1[fwd] == t1[rev]


def test_link_model_validation(ring_world):
    topo = ring_world.topo
    with pytest.raises(ValueError, match="latency"):
        ConstantLink(latency=-1.0).bind(topo, 4.0)
    with pytest.raises(ValueError, match="bandwidth"):
        ConstantLink(bandwidth=0.0).bind(topo, 4.0)
    with pytest.raises(ValueError, match="directed"):
        TableLink(latency=np.zeros(3)).bind(topo, 4.0)
    # bytes / bandwidth prices the wire exactly
    t = ConstantLink(latency=1.0, bandwidth=8.0).bind(topo, 16.0)
    assert (t == 3.0).all()


def test_registries():
    assert isinstance(make_node_model("straggler", frac=0.5), StragglerStep)
    assert isinstance(make_link_model("table"), TableLink)
    with pytest.raises(ValueError, match="unknown"):
        make_node_model("warp")
    with pytest.raises(ValueError, match="unknown"):
        make_link_model("warp")


# ------------------------------------------------------------- 6. schedule

def test_loop_fused_bit_identical_with_deadline(ba_world):
    """The clock rides the scan carry: loop and fused agree bit-exactly on
    params AND the full time/arrival accounting, heterogeneous models,
    per-node transport, deadline ticks."""
    runs = {}
    for mode in ("loop", "fused"):
        runs[mode] = _run(
            _with(ba_world, timing=HET),
            comm=CommConfig(codec="int8", trigger_threshold=0.3),
            schedule=Schedule(rounds=4, eval_every=2, deadline=4.0,
                              mode=mode))
    a, b = runs["loop"], runs["fused"]
    assert _params_equal(a.params, b.params)
    assert _fingerprint(a) == _fingerprint(b)
    assert a.sim_time_history == b.sim_time_history
    assert a.arrived_history == b.arrived_history
    assert np.array_equal(np.asarray(a.time_state.t),
                          np.asarray(b.time_state.t))


def test_fused_program_is_one_scan(ring_world):
    """The whole K-round schedule with the clock enabled still lowers to
    exactly ONE lax.scan (plus the per-round local-training scans nested
    INSIDE its body — we count only top-level scan equations)."""
    exp = Experiment(_with(ring_world, timing=HET), "decdiff+vt",
                     comm=CommConfig(codec="int8", trigger_threshold=0.3),
                     schedule=Schedule(rounds=4, eval_every=2, deadline=4.0),
                     **TINY)
    fused = exp._fused_program(4, 2)
    carry = ((exp.params, exp.opt_state) + exp._get_states() + (exp.rng,))
    jaxpr = jax.make_jaxpr(lambda c: fused(c))(carry)
    scans = [e for e in jaxpr.jaxpr.eqns if e.primitive.name == "scan"]
    pjits = [e for e in jaxpr.jaxpr.eqns if e.primitive.name == "pjit"]
    if pjits:  # the jitted program wraps the scan one level down
        inner = pjits[0].params["jaxpr"].jaxpr
        scans = [e for e in inner.eqns if e.primitive.name == "scan"]
    assert len(scans) == 1


def test_dense_sparse_parity_with_deadline(ba_world):
    """Both layouts agree bit-exactly under heterogeneous timing with a
    deadline (participation=1: no layout-shaped draws)."""
    runs = {lay: _run(_with(ba_world, timing=HET), layout=lay,
                      schedule=Schedule(rounds=3, eval_every=3, deadline=4.0))
            for lay in ("dense", "sparse")}
    assert _params_equal(runs["dense"].params, runs["sparse"].params)
    assert (runs["dense"].sim_time_history
            == runs["sparse"].sim_time_history)
    assert runs["dense"].arrived_history == runs["sparse"].arrived_history


def test_vmap_shardmap_parity_with_deadline(ba_world):
    runs = {be: _run(_with(ba_world, timing=HET), backend=be,
                     comm=CommConfig(codec="int8", policy="adaptive",
                                     target_trigger=0.7, per_edge=True),
                     schedule=Schedule(rounds=3, eval_every=3, deadline=4.0))
            for be in ("vmap", "shard_map")}
    assert _params_equal(runs["vmap"].params, runs["shard_map"].params)
    assert runs["vmap"].sim_time_history == runs["shard_map"].sim_time_history
    assert runs["vmap"].arrived_history == runs["shard_map"].arrived_history


# ------------------------------------------------------------ property lane

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYP = True
except Exception:  # pragma: no cover
    HAVE_HYP = False

if HAVE_HYP:

    @pytest.mark.fuzz
    @settings(deadline=None, max_examples=10)
    @given(sigma=st.floats(0.0, 1.5), seed=st.integers(0, 2 ** 16),
           dl=st.floats(0.5, 8.0))
    def test_fuzz_clock_invariants(sigma, seed, dl):
        """For any lognormal node/link draw and any deadline: sim_time is
        strictly increasing by exactly the deadline per round, arrived
        fractions live in [0, 1], realized costs are nonneg and at most
        the deadline cap, and params stay finite."""
        from repro.models.mlp_cnn import make_mlp

        world = World.synthetic(
            dataset="synth-mnist", nodes=4, topology="ring", seed=3,
            scale=0.02, model=make_mlp(num_classes=10, hidden=(16,)),
            timing=Timing(node=LognormalStep(sigma=sigma, seed=seed),
                          link=LognormalLink(seed=seed + 1)))
        exp = Experiment(world, "decdiff+vt",
                         schedule=Schedule(rounds=3, eval_every=3,
                                           deadline=dl),
                         steps_per_round=2, batch_size=8, lr=0.1,
                         momentum=0.9, seed=1)
        exp.run()
        ts = np.asarray(exp.sim_time_history)
        assert np.allclose(np.diff(np.concatenate([[0.0], ts])), dl)
        assert all(0.0 <= a <= 1.0 for a in exp.arrived_history)
        cost = np.asarray(exp.time_state.last_cost)
        dt = np.asarray(exp.bound_timing.step_time(jnp.int32(2)))
        assert (cost >= 0).all() and (cost <= dl + 1e-5).all()
        assert (cost <= 2 * dt + 1e-5).all()
        assert all(np.isfinite(np.asarray(p)).all()
                   for p in jax.tree.leaves(exp.params))

    @pytest.mark.fuzz
    @settings(deadline=None, max_examples=20)
    @given(t=st.integers(1, 5), n=st.integers(2, 12),
           r=st.integers(0, 40))
    def test_fuzz_past_end_rules(t, n, r):
        from repro.timing.models import past_end_index

        assert int(past_end_index(jnp.int32(r), t, "wrap")) == r % t
        assert int(past_end_index(jnp.int32(r), t, "clamp")) == min(r, t - 1)
