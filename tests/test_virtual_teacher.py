"""Virtual-Teacher loss (paper Eq. 7-8): closed form vs materialized teacher."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (a dev dependency; CI installs it)")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.virtual_teacher import (
    cross_entropy_loss,
    make_loss_fn,
    soft_labels,
    teacher_entropy,
    vt_kl_loss,
)


def _materialized_kl(logits, labels, beta):
    p_t = soft_labels(labels, logits.shape[-1], beta)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    log_pt = jnp.log(jnp.maximum(p_t, 1e-30))
    return jnp.mean(jnp.sum(p_t * (log_pt - logp), axis=-1))


@settings(deadline=None, max_examples=25)
@given(seed=st.integers(0, 2**16), b=st.integers(1, 8), v=st.integers(2, 50),
       beta=st.floats(0.5, 0.999))
def test_closed_form_matches_materialized(seed, b, v, beta):
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.standard_normal((b, v)) * 3, jnp.float32)
    y = jnp.asarray(rng.integers(0, v, b), jnp.int32)
    got = vt_kl_loss(z, y, beta=beta)
    want = _materialized_kl(z, y, beta)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_beta_one_reduces_to_cross_entropy():
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.standard_normal((16, 10)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, 16), jnp.int32)
    np.testing.assert_allclose(vt_kl_loss(z, y, beta=1.0),
                               cross_entropy_loss(z, y), rtol=1e-6)


def test_kl_nonnegative():
    rng = np.random.default_rng(1)
    z = jnp.asarray(rng.standard_normal((32, 26)) * 5, jnp.float32)
    y = jnp.asarray(rng.integers(0, 26, 32), jnp.int32)
    assert float(vt_kl_loss(z, y, beta=0.9)) >= -1e-6


def test_minimum_at_teacher_distribution():
    """Loss is 0 when the model outputs exactly p_t."""
    v, beta = 10, 0.9
    y = jnp.arange(4) % v
    logits = jnp.log(soft_labels(y, v, beta))
    assert abs(float(vt_kl_loss(logits, y, beta=beta))) < 1e-5


def test_gradient_is_softmax_minus_teacher():
    rng = np.random.default_rng(2)
    z = jnp.asarray(rng.standard_normal((6, 12)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 12, 6), jnp.int32)
    beta = 0.95
    g = jax.grad(lambda zz: vt_kl_loss(zz, y, beta=beta))(z)
    expect = (jax.nn.softmax(z, -1) - soft_labels(y, 12, beta)) / 6
    np.testing.assert_allclose(g, expect, rtol=1e-4, atol=1e-6)


def test_teacher_entropy_limits():
    assert abs(float(teacher_entropy(1.0, 10))) < 1e-6  # delta -> 0 entropy
    h_uniform = float(teacher_entropy(0.1, 10))  # beta=1/V -> uniform
    np.testing.assert_allclose(h_uniform, np.log(10), rtol=1e-5)


def test_where_mask():
    rng = np.random.default_rng(3)
    z = jnp.asarray(rng.standard_normal((4, 5)), jnp.float32)
    y = jnp.asarray([0, 1, 2, 3], jnp.int32)
    mask = jnp.asarray([True, True, False, False])
    got = vt_kl_loss(z, y, beta=0.9, where=mask)
    want = vt_kl_loss(z[:2], y[:2], beta=0.9)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_loss_factory():
    assert make_loss_fn("ce") is cross_entropy_loss
    fn = make_loss_fn("vt", beta=0.9)
    z = jnp.ones((2, 3))
    y = jnp.zeros((2,), jnp.int32)
    assert jnp.isfinite(fn(z, y))
    try:
        make_loss_fn("nope")
        raise AssertionError("expected ValueError")
    except ValueError:
        pass
